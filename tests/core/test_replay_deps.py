"""Round-trip coverage for ``to_replay(deps=True)``: the RAW/WAR holds
derived from a captured trace must never let a dependent request inject
(hence issue) before its producer has been served — on homogeneous and
heterogeneous multi-group systems — and the dependency extractor itself
is property-checked against a brute-force reference."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):
        return lambda f: f

    def given(**kw):
        return lambda f: f

    class st:                           # noqa: N801
        @staticmethod
        def integers(*a, **kw):
            return None

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

from repro.core import (ControllerConfig, FrontendConfig, Simulator,
                        compile_system)
from repro.trace import audit, capture, to_replay
from repro.trace.capture import _replay_deps

pytestmark = pytest.mark.device_timings


# ---------------------------------------------------------------------------
# The extractor vs a brute-force reference (pure numpy, no compiles)
# ---------------------------------------------------------------------------

def _ref_deps(chan, bank, row, is_wr):
    """O(n^2) reference: scan backwards for the most recent earlier
    opposite-kind access to the same (chan, bank, row).  Same-kind
    accesses in between do not sever the dependency (RAW reaches back
    past earlier reads to the last write, and vice versa)."""
    n = len(chan)
    dep = np.full(n, -1, np.int64)
    for k in range(n):
        for j in range(k - 1, -1, -1):
            if (chan[j], bank[j], row[j]) != (chan[k], bank[k], row[k]):
                continue
            if bool(is_wr[j]) != bool(is_wr[k]):
                dep[k] = j
                break
    return dep


def _random_access_pattern(rng, n):
    return (rng.integers(0, 2, n), rng.integers(0, 3, n),
            rng.integers(0, 4, n), rng.integers(0, 2, n))


@needs_hypothesis
@settings(max_examples=50)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64))
def test_replay_deps_matches_reference(seed, n):
    rng = np.random.default_rng(seed)
    chan, bank, row, is_wr = _random_access_pattern(rng, n)
    assert (_replay_deps(chan, bank, row, is_wr)
            == _ref_deps(chan, bank, row, is_wr)).all()


def test_replay_deps_matches_reference_fallback(rng):
    for n in (1, 7, 64, 200):
        chan, bank, row, is_wr = _random_access_pattern(rng, n)
        assert (_replay_deps(chan, bank, row, is_wr)
                == _ref_deps(chan, bank, row, is_wr)).all()


def test_replay_deps_kinds():
    # W R R W W R at one address: RAW -> 0, WAR from the last read pair
    chan = np.zeros(6, np.int64)
    bank = np.zeros(6, np.int64)
    row = np.zeros(6, np.int64)
    is_wr = np.asarray([1, 0, 0, 1, 1, 0])
    dep = _replay_deps(chan, bank, row, is_wr)
    assert dep.tolist() == [-1, 0, 0, 2, 2, 4]


# ---------------------------------------------------------------------------
# Engine round-trip: producers are served before dependents inject
# ---------------------------------------------------------------------------

def _flat_bank(msys, rs):
    """Recover the flat bank id of each stream record from its padded
    sub vector, through the record's own group geometry."""
    out = np.zeros(len(rs), np.int64)
    for k in range(len(rs)):
        g = msys.groups[int(msys.chan_group[int(rs.chan[k])])]
        counts = g.cspec.level_counts
        b = 0
        for i in range(1, len(counts)):
            b = b * int(counts[i]) + int(rs.sub[k, i - 1])
        out[k] = b
    return out


def _check_producers_served_first(msys, rs, tr2):
    """For every dependent k with producer j = dep[k]: in the replayed
    trace, k's injection clock (arrive) is strictly after j's final
    command issued.  Requests are matched per (chan, bank, row) key, in
    which replay preserves stream order."""
    from repro.core import spec as S
    if msys.n_groups == 1:
        fx = np.asarray(msys.groups[0].cspec.cmd_fx)[tr2.cmd]
    else:
        fx_lut = np.zeros((msys.n_groups, len(tr2.cmd_names)), np.int64)
        for g, grp in enumerate(msys.groups):
            fx_lut[g, msys.group_cmd_maps[g]] = grp.cspec.cmd_fx
        fx = fx_lut[tr2.group, tr2.cmd]
    final = ((fx & (S.FX_FINAL_RD | S.FX_FINAL_WR)) != 0) & (tr2.arrive >= 0)
    chan2 = np.zeros(len(tr2.clk), np.int64) if tr2.chan is None \
        else np.asarray(tr2.chan, np.int64)
    order = np.argsort(np.asarray(tr2.arrive), kind="stable")
    order = order[final[order]]

    bank = _flat_bank(msys, rs)
    key = lambda i: (int(rs.chan[i]), int(bank[i]), int(rs.row[i]))
    # per-address-key event lists, in injection (= stream) order
    served = {}
    for e in order:
        served.setdefault((int(chan2[e]), int(tr2.bank[e]),
                           int(tr2.row[e])), []).append(e)
    pos = {}
    checked = 0
    for k in range(len(rs)):
        i = pos.get(key(k), 0)
        pos[key(k)] = i + 1
        j = int(rs.dep[k])
        if j < 0:
            continue
        evs = served.get(key(k), [])
        jpos = sum(1 for m in range(j) if key(m) == key(j))
        if i >= len(evs) or jpos >= len(evs):
            continue                     # not served within the horizon
        inject_clk = int(tr2.arrive[evs[i]])
        producer_serve_clk = int(tr2.clk[evs[jpos]])
        assert inject_clk > producer_serve_clk, \
            f"dep {k}->{j}: injected at {inject_clk}, producer " \
            f"served at {producer_serve_clk}"
        checked += 1
    return checked


def test_deps_roundtrip_homogeneous():
    src = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig())
    _, dense = src.run(1200, interval=4.0, read_ratio=0.5, trace=True)
    tr = capture(src.cspec, dense, controller=src.controller,
                 frontend=src.frontend)
    rs = to_replay(tr, src.cspec, deps=True)
    assert int(np.sum(rs.dep >= 0)) > 5

    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    _, dense2 = sim.run(4000, trace=True)
    tr2 = capture(sim.cspec, dense2, controller=sim.controller,
                  frontend=sim.frontend)
    rep = audit(sim.cspec, tr2, check_fingerprint=False)
    assert rep.ok, "; ".join(str(v) for v in rep.violations[:5])
    checked = _check_producers_served_first(sim.msys, rs, tr2)
    assert checked > 5                   # the property was exercised


def test_deps_roundtrip_hetero_multigroup():
    """The hetero path: merged command namespace, per-group fx lookup,
    per-group bank geometry — RAW/WAR holds still enforced behind the
    CXL-style link."""
    msys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=1),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=1, link_latency=40),
    ])
    src = Simulator(system=msys)
    _, dense = src.run(1500, interval=4.0, read_ratio=0.5, trace=True)
    tr = capture(msys, dense, controller=src.controller,
                 frontend=src.frontend)
    rs = to_replay(tr, msys, deps=True)
    assert int(np.sum(rs.dep >= 0)) > 0
    assert len(set(np.unique(rs.chan))) == 2     # both groups trafficked

    sim = Simulator(system=msys,
                    frontend=FrontendConfig(pattern="trace", probes=False),
                    replay=rs)
    _, dense2 = sim.run(5000, trace=True)
    tr2 = capture(msys, dense2, controller=sim.controller,
                  frontend=sim.frontend)
    rep = audit(msys, tr2, check_fingerprint=False)
    assert rep.ok, "; ".join(str(v) for v in rep.violations[:5])
    checked = _check_producers_served_first(msys, rs, tr2)
    assert checked > 0
