"""Controller workflow tests: FR-FCFS, refresh, BlockHammer, PRAC predicates."""
import numpy as np
import pytest

from repro.core import ControllerConfig, FrontendConfig, Simulator, throughput_gbps


def test_frfcfs_prefers_row_hits():
    """Sequential streaming under FRFCFS ~> few ACTs per many RDs."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(probes=False))
    stats = sim.run(8000, interval=2.0, read_ratio=1.0)
    counts = dict(zip(sim.cspec.cmd_names, stats.cmd_counts.tolist()))
    assert counts["RD"] > 5 * max(counts["ACT"], 1), counts


def test_fcfs_vs_frfcfs_random_traffic():
    """FR-FCFS should not lose to FCFS."""
    kw = dict(frontend=FrontendConfig(pattern="random", probes=False))
    tp = {}
    for sched in ("FRFCFS", "FCFS"):
        sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                        controller=ControllerConfig(scheduler=sched), **kw)
        stats = sim.run(8000, interval=2.0, read_ratio=1.0)
        tp[sched] = throughput_gbps(sim.cspec, stats)
    assert tp["FRFCFS"] >= tp["FCFS"] * 0.99


def test_refresh_issued_at_nrefi():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(stream=False, probes=False))
    n = 4 * sim.cspec.timings["nREFI"] + 100
    stats = sim.run(n)
    counts = dict(zip(sim.cspec.cmd_names, stats.cmd_counts.tolist()))
    # idle system: one REFab per rank per nREFI window
    ranks = sim.cspec.n_refresh_units
    assert counts["REFab"] == 4 * ranks, counts


def test_refresh_preempts_under_load():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    frontend=FrontendConfig(probes=False))
    n = 3 * sim.cspec.timings["nREFI"]
    stats = sim.run(n, interval=1.0, read_ratio=1.0)
    counts = dict(zip(sim.cspec.cmd_names, stats.cmd_counts.tolist()))
    assert counts["REFab"] >= 2, "refresh starved under load"


def test_blockhammer_defers_hammering():
    """A single-row hammer pattern must see ACTs deferred by the predicate."""
    import jax.numpy as jnp
    from repro.core import controller as C

    # custom frontend-free scenario: hammer via extra predicate accounting
    base = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     controller=ControllerConfig(blockhammer_threshold=8),
                     frontend=FrontendConfig(pattern="random", probes=False))
    # random pattern with tiny row space => heavy per-row reuse
    base.cspec.rows = 2     # hammer: only 2 distinct rows ever targeted
    stats = base.run(20000, interval=2.0, read_ratio=1.0)
    assert int(stats.deferred) > 0, "BlockHammer predicate never fired"


def test_blockhammer_neutral_on_benign_traffic():
    cfg = ControllerConfig(blockhammer_threshold=512)
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", controller=cfg,
                    frontend=FrontendConfig(probes=False))
    plain = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      frontend=FrontendConfig(probes=False))
    s1 = sim.run(6000, interval=2.0, read_ratio=1.0)
    s2 = plain.run(6000, interval=2.0, read_ratio=1.0)
    t1, t2 = (throughput_gbps(sim.cspec, s) for s in (s1, s2))
    assert t1 >= t2 * 0.95, "BlockHammer tanked benign throughput"


def test_prac_recovery_blocks_and_resets():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig(prac_threshold=16),
                    frontend=FrontendConfig(pattern="random", probes=False))
    sim.cspec.rows = 4
    stats = sim.run(20000, interval=2.0, read_ratio=1.0)
    counts = dict(zip(sim.cspec.cmd_names, stats.cmd_counts.tolist()))
    nrefi_refs = 20000 // sim.cspec.timings["nREFI"] + 1
    ranks = sim.cspec.n_refresh_units
    # PRAC alerts ride the refresh engine -> more REFab than time-based alone
    assert counts["REFab"] > nrefi_refs * ranks, counts


def test_user_predicate_composes():
    """Paper §2: arbitrary lambdas can be injected into the base workflow."""
    import jax.numpy as jnp

    def no_writes_ever(cspec, ctx):
        return ctx.cand_cmd != jnp.int32(cspec.id_WR)

    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig(
                        extra_predicates=(no_writes_ever,)),
                    frontend=FrontendConfig(probes=False))
    stats = sim.run(4000, interval=2.0, read_ratio=0.5)
    counts = dict(zip(sim.cspec.cmd_names, stats.cmd_counts.tolist()))
    assert counts["WR"] == 0
    assert counts["RD"] > 0


def test_queue_backpressure():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                    controller=ControllerConfig(queue_depth=4),
                    frontend=FrontendConfig(pattern="random", probes=False))
    stats = sim.run(4000, interval=1.0, read_ratio=1.0)
    # queue of 4 can't sustain 1 req/cycle of random misses
    assert int(stats.reads_done) < 4000
