"""Compiler input validation + optional compile-time lint gate.

Regression coverage for the hardened error paths: unknown
``timing_overrides`` keys fail loudly at compile_spec, latency
expressions with undeclared tokens name the standard/constraint they
came from, and the ``lint=``/``REPRO_SPEC_LINT`` hook wires the spec
linter into ``compile_spec`` itself."""
import pytest

import repro.core.standards  # noqa: F401  (register all standards)
from repro.core import spec as S
from repro.core.compile import compile_spec, resolve_latency


def _timings(std="DDR4", preset="DDR4_2400R"):
    return dict(S.get_standard(std).timing_presets[preset])


# ---------------------------------------------------------------------------
# satellite: unknown timing_overrides keys
# ---------------------------------------------------------------------------

def test_unknown_override_key_raises():
    with pytest.raises(ValueError) as ei:
        compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     timing_overrides={"tRRD": 4})
    msg = str(ei.value)
    assert "tRRD" in msg and "unknown" in msg
    # the error teaches the valid namespace
    assert "nRRD_S" in msg


def test_multiple_unknown_override_keys_all_named():
    with pytest.raises(ValueError) as ei:
        compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     timing_overrides={"tRRD": 4, "nBOGUS": 1, "nCL": 20})
    msg = str(ei.value)
    assert "nBOGUS" in msg and "tRRD" in msg


def test_valid_overrides_still_accepted():
    cs = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      timing_overrides={"nCL": 20, "tCK_ps": 1000})
    assert cs.timings["nCL"] == 20


# ---------------------------------------------------------------------------
# satellite: resolve_latency names its context
# ---------------------------------------------------------------------------

def test_resolve_latency_unknown_token_named():
    t = _timings()
    with pytest.raises(ValueError) as ei:
        resolve_latency("nCL+nBOGUS", t)
    msg = str(ei.value)
    assert "nBOGUS" in msg and "'nCL+nBOGUS'" in msg
    assert "unknown timing parameter" in msg


def test_resolve_latency_error_carries_context():
    with pytest.raises(ValueError) as ei:
        resolve_latency("nMISSING", _timings(),
                        context="DDR4 constraint PRE->ACT@bank")
    assert str(ei.value).startswith("DDR4 constraint PRE->ACT@bank")


def test_compile_error_names_standard_and_constraint():
    std = S.get_standard("DDR4")
    bogus = S.TimingConstraint(level="bank", preceding=["PRE"],
                               following=["PRE"], latency="nBOGUS")
    mut = type("DDR4_badtok", (std,), {
        "timing_constraints": tuple(std.timing_constraints) + (bogus,)})
    with pytest.raises(ValueError) as ei:
        compile_spec(mut, "DDR4_8Gb_x8", "DDR4_2400R")
    msg = str(ei.value)
    assert "DDR4" in msg and "PRE->PRE@bank" in msg and "nBOGUS" in msg


# ---------------------------------------------------------------------------
# compile-time lint hook
# ---------------------------------------------------------------------------

BAD_TRC = {"nRC": 1}        # valid key, physically broken value


def test_lint_off_by_default():
    cs = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      timing_overrides=dict(BAD_TRC))
    assert cs.timings["nRC"] == 1


def test_lint_error_mode_raises():
    with pytest.raises(ValueError, match="spec lint failed at compile"):
        compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     timing_overrides=dict(BAD_TRC), lint="error")


def test_lint_warn_mode_prints_and_compiles(capsys):
    cs = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      timing_overrides=dict(BAD_TRC), lint="warn")
    assert cs.timings["nRC"] == 1
    out = capsys.readouterr().out
    assert "trc-decomposition" in out


def test_lint_error_mode_clean_spec_passes():
    cs = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", lint="error")
    assert cs.timings["nRC"] > 1


def test_lint_mode_from_environment(monkeypatch):
    monkeypatch.setenv("REPRO_SPEC_LINT", "error")
    with pytest.raises(ValueError, match="spec lint failed at compile"):
        compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     timing_overrides=dict(BAD_TRC))
    # an explicit argument beats the environment
    monkeypatch.setenv("REPRO_SPEC_LINT", "off")
    with pytest.raises(ValueError, match="spec lint failed at compile"):
        compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     timing_overrides=dict(BAD_TRC), lint="error")


def test_lint_mode_validated():
    with pytest.raises(ValueError, match="lint mode"):
        compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", lint="loud")
