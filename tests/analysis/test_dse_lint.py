"""Sweep pre-lint gate: override-carrying design-space corners are spec-
linted before any compile group is built (``SweepSpec.lint_specs``)."""
import pytest

from repro.analysis.speclint import SpecLintError
from repro.core import engine as E
from repro.dse import Composition, SweepSpec, execute
from repro.dse.executor import lint_sweep_systems

BAD_DDR4 = ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", {"nRC": 1})
OK_DDR4 = ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", {"nCL": 20})


def test_bad_override_corner_fails_fast():
    spec = SweepSpec(systems=("DDR5", BAD_DDR4), intervals=(8.0,),
                     n_cycles=400)
    with pytest.raises(SpecLintError) as ei:
        lint_sweep_systems(spec.expand())
    rep = ei.value.report
    assert rep.target == "sweep-pre-lint"
    assert "trc-decomposition" in rep.rules_fired()


def test_execute_gates_before_compiling():
    spec = SweepSpec(systems=(BAD_DDR4,), intervals=(8.0,), n_cycles=400)
    with pytest.raises(SpecLintError):
        execute(spec, cache=E.RunCache())


def test_clean_overrides_pass_the_gate():
    spec = SweepSpec(systems=(OK_DDR4,), intervals=(8.0,), n_cycles=400)
    lint_sweep_systems(spec.expand())       # must not raise


def test_no_override_systems_are_skipped():
    # registered standards are gated elsewhere; the sweep lint only pays
    # for override-carrying corners
    spec = SweepSpec(systems=("DDR4", "DDR5"), intervals=(8.0,),
                     n_cycles=400)
    lint_sweep_systems(spec.expand())       # must not raise


def test_composition_member_overrides_are_linted():
    comp = Composition(((BAD_DDR4, 2), ("DDR5", 2)))
    spec = SweepSpec(systems=(comp,), intervals=(8.0,), n_cycles=400)
    with pytest.raises(SpecLintError) as ei:
        lint_sweep_systems(spec.expand())
    assert "trc-decomposition" in ei.value.report.rules_fired()


def test_opt_out_runs_the_violating_corner():
    spec = SweepSpec(systems=(BAD_DDR4,), intervals=(8.0,),
                     read_ratios=(1.0,), n_cycles=400, lint_specs=False)
    res = execute(spec, cache=E.RunCache())
    assert len(res.points) == 1
