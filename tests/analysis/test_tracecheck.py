"""Trace-safety linter: synthetic anti-pattern fixtures, suppression
syntax, traced-context discovery, and the clean-tree gate over the real
hot-path modules."""
import os
import textwrap

import repro
from repro.analysis.tracecheck import (JNP_ALLOWLIST, ContextIndex,
                                       lint_paths, load_modules)

# repro is a namespace package: locate it via __path__, not __file__
REPRO_DIR = os.path.abspath(list(repro.__path__)[0])
SRC_ROOT = os.path.dirname(REPRO_DIR)


def _write_pkg(tmp_path, source, name="pkg"):
    d = tmp_path / name
    d.mkdir()
    (d / "__init__.py").write_text("")
    (d / "mod.py").write_text(textwrap.dedent(source))
    return str(d)


BAD = """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from functools import partial


    def body(carry, x):
        if carry > 0:                      # TS101
            carry = carry - 1
        n = int(x)                         # TS102
        v = x.item()                       # TS102
        h = np.tanh(carry)                 # TS103
        while x > 0:                       # TS101
            x = x - 1
        ok = 0 if x is None else 1         # exempt: identity test
        m = len(carry)                     # exempt producer
        return carry, (n, v, h, ok, m)


    def run(init, xs):
        return jax.lax.scan(body, init, xs)
"""


def test_rules_fire_on_synthetic_scan_body(tmp_path):
    rep = lint_paths([_write_pkg(tmp_path, BAD)])
    fired = rep.rules_fired()
    assert fired.get("TS101") == 2
    assert fired.get("TS102") == 2
    assert fired.get("TS103") == 1
    assert fired.get("TS105") == 1          # pkg.mod is not allowlisted
    assert not rep.ok()


def test_unreferenced_function_is_not_a_traced_context(tmp_path):
    # the same anti-patterns in a function nothing scans/jits: no finding
    src = textwrap.dedent(BAD).split("def run")[0]
    rep = lint_paths([_write_pkg(tmp_path, src)])
    assert rep.rules_fired().get("TS101") is None


def test_local_partial_alias_marks_scan_body(tmp_path):
    src = """
        import jax
        from functools import partial


        def cycle(carry, x, cfg):
            if carry > 0:                  # TS101 via alias resolution
                pass
            return carry, x


        def run(init, xs, cfg):
            body = partial(cycle, cfg=cfg)
            return jax.lax.scan(body, init, xs)
    """
    rep = lint_paths([_write_pkg(tmp_path, src)])
    assert rep.rules_fired().get("TS101") == 1


def test_transitive_callee_is_traced(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp


        def helper(q):
            v = jnp.sum(q)
            n = int(v)                     # TS102, reached through body
            return n


        def body(carry, x):
            return carry, helper(carry)


        def run(init, xs):
            return jax.lax.scan(body, init, xs)
    """
    rep = lint_paths([_write_pkg(tmp_path, src)])
    assert rep.rules_fired().get("TS102") == 1


def test_suppression_comment_and_skip_file(tmp_path):
    src = """
        import jax


        def body(carry, x):
            if carry > 0:  # lint: ignore[ts101]
                pass
            n = int(carry)                 # still flagged
            return carry, n


        def run(init, xs):
            return jax.lax.scan(body, init, xs)
    """
    rep = lint_paths([_write_pkg(tmp_path, src)])
    fired = rep.rules_fired()
    assert fired.get("TS101") is None       # suppressed
    assert fired.get("TS102") == 1          # suppression is per-rule

    skip = "# lint: skip-file\n" + textwrap.dedent(src)
    d = tmp_path / "pkg2"
    d.mkdir()
    (d / "__init__.py").write_text("")
    (d / "mod.py").write_text(skip)
    rep2 = lint_paths([str(d)])
    assert not rep2.findings


def test_cache_keyed_mutable_capture(tmp_path):
    src = """
        _KNOBS = [1, 2, 3]


        def make(sim):
            return sim.run(extra_predicates=(
                lambda cspec, ctx: _KNOBS,))
    """
    rep = lint_paths([_write_pkg(tmp_path, src)])
    assert rep.rules_fired().get("TS104") == 1


def test_engine_scan_body_is_discovered():
    mods = load_modules([REPRO_DIR], root=SRC_ROOT)
    idx = ContextIndex(mods)
    ctxs = {f"{m}:{q}" for (m, q) in idx.contexts}
    # the partial(cycle, ...) -> _scan_cycles -> lax.scan chain resolves
    assert "repro.core.engine:make_run.cycle" in ctxs
    # and the hot-path callees are transitively traced
    for want in ("repro.core.controller:controller_step",
                 "repro.core.device:issue",
                 "repro.core.frontend:system_frontend_insert"):
        assert want in ctxs, want
    # the scan body's params count as traced values
    key = ("repro.core.engine", "make_run.cycle")
    assert idx.contexts[key] is True


def test_hot_path_modules_lint_clean():
    paths = [os.path.join(REPRO_DIR, "core", f"{m}.py")
             for m in ("engine", "controller", "frontend", "device")]
    # lint the whole package so cross-module contexts resolve, then gate
    # on the hot-path files specifically
    rep = lint_paths([REPRO_DIR], root=SRC_ROOT)
    hot = [f for f in rep.findings if f.path in paths]
    assert not hot, [f.render() for f in hot]
    # and the whole tree is clean too (TS105 allowlist up to date)
    assert rep.ok(strict=True), rep.summary()


def test_allowlist_names_only_real_modules():
    mods = load_modules([REPRO_DIR], root=SRC_ROOT)
    missing = [m for m in JNP_ALLOWLIST if m != "repro.compat"
               and m not in mods]
    assert not missing, missing
