"""Spec linter: clean standards stay clean, seeded defects are caught.

The two acceptance halves of the spec-lint pass:

* zero false positives — every registered standard (and the reference
  heterogeneous composition) lints with no error- or warn-severity
  findings;
* 100% detection — each mutation-seeded defect class fires its rule
  exactly once with the right rule id (``repro.verify.spec_mutation``).
"""
import dataclasses

import pytest

import repro.core.standards  # noqa: F401  (register all standards)
from repro.analysis import (ERROR, RULES, lint_all, lint_compiled,
                            lint_spec, lint_system)
from repro.core.compile import compile_spec, compile_system
from repro.core.spec import all_standards
from repro.verify import spec_mutation as M

ALL_STANDARDS = sorted(all_standards())


# ---------------------------------------------------------------------------
# zero false positives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("std", ALL_STANDARDS)
def test_registered_standard_lints_clean(std):
    rep = lint_spec(std)
    assert rep.ok(strict=True), rep.summary()
    assert rep.meta["compiled"] is True


def test_lint_all_covers_every_registered_standard():
    reps = lint_all()
    assert sorted(reps) == ALL_STANDARDS
    assert all(r.ok(strict=True) for r in reps.values())


def test_hetero_composition_lints_clean():
    msys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ])
    rep = lint_system(msys)
    assert rep.ok(strict=True), rep.summary()
    assert len(rep.meta["groups"]) == 2


def test_multichannel_refresh_stagger_stays_clean():
    # 4-channel DDR5: staggered refresh windows must not overlap
    rep = lint_spec("DDR5", channels=4)
    assert rep.ok(strict=True), rep.summary()


# ---------------------------------------------------------------------------
# 100% detection of seeded defects
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mutator", sorted(M.MUTATORS))
def test_mutator_fires_expected_rule_exactly_once(mutator):
    inj = M.inject("DDR4", mutator)
    assert inj is not None
    hits = inj.hits()
    assert len(hits) == 1, (inj.rule, inj.report.summary())
    assert hits[0].rule == inj.rule
    assert hits[0].severity == ERROR


def test_mutation_matrix_full_detection():
    m = M.spec_mutation_matrix(ALL_STANDARDS)
    missed = {k: v for k, v in m.items() if v.startswith("MISSED")}
    assert not missed, missed
    # every mutator must be exercised (not skipped) on at least one std
    for mut in M.MUTATORS:
        assert any(v == "detected" for (s, mm), v in m.items()
                   if mm == mut), mut


def test_trc_violation_names_rationale_and_values():
    inj = M.inject("DDR5", "trc-shrink")
    (f,) = inj.hits()
    assert f.rule == "trc-decomposition"
    d = dict(f.data)
    assert d["lhs_value"] == d["rhs_value"] - 1
    assert "JEDEC" in f.message


def test_coverage_hole_names_the_missing_pair():
    inj = M.inject("DDR4", "coverage-delete")
    (f,) = inj.hits()
    assert dict(f.data)["prev"] == "PRE"
    assert "zero cycles apart" in f.message


def test_dominated_row_reports_both_rows():
    inj = M.inject("DDR4", "dominated-inject")
    (f,) = inj.hits()
    assert len(f.rows) == 2
    assert dict(f.data)["dominated"] != dict(f.data)["dominator"]


def test_unknown_token_skips_compile():
    inj = M.inject("DDR4", "unknown-token")
    assert inj.report.meta["compiled"] is False
    assert "nBOGUS" in inj.hits()[0].message


def test_ring_corruption_detected_on_compiled_spec():
    cspec = compile_spec("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    bad = dataclasses.replace(cspec, ring_depth=cspec.ring_depth - 1)
    rep = lint_compiled(bad)
    assert [f.rule for f in rep.errors] == ["ring-capacity"]
    # the pristine table is clean
    assert lint_compiled(cspec).ok(strict=True)


def test_refresh_stagger_overlap_warns():
    # squeeze nREFI so per-channel stagger spacing < nRFC but refresh
    # itself stays schedulable: warn, not error
    import repro.core.spec as S
    std = S.get_standard("DDR4")
    t = dict(std.timing_presets["DDR4_2400R"])
    rep = lint_spec("DDR4", timing_overrides={"nREFI": t["nRFC"] * 3},
                    channels=4)
    assert rep.ok() and not rep.ok(strict=True)
    assert any(f.rule == "refresh-headroom" for f in rep.warnings)


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------

def test_registry_rule_ids_unique_and_scoped():
    assert len(RULES) >= 12
    for rid, rule in RULES.items():
        assert rule.id == rid
        assert rule.scope in ("standard", "table")
        assert rule.rationale, rid


def test_family_gated_rule_only_applies_to_family():
    from repro.analysis.rules import applicable
    vrr = RULES["vrr-covers-row-cycle"]
    assert applicable(vrr, "DDR5_VRR")
    assert not applicable(vrr, "DDR5")
    # and the rule actually fires on a family member when violated
    rep = lint_spec("DDR5_VRR", timing_overrides={"nVRR": 1})
    assert any(f.rule == "vrr-covers-row-cycle" for f in rep.errors)
    # the same override key does not exist on plain DDR5
    assert "nVRR" not in dict(
        __import__("repro.core.spec", fromlist=["get_standard"])
        .get_standard("DDR5").timing_presets["DDR5_4800B"])


def test_unused_param_warns():
    import repro.core.spec as S
    std = S.get_standard("DDR4")
    mut = type("DDR4_unused", (std,), {
        "timing_params": tuple(std.timing_params) + ("nNEVER",)})
    rep = lint_spec(mut)
    hits = [f for f in rep.warnings if f.rule == "unused-param"]
    assert len(hits) == 1 and "nNEVER" in hits[0].message
