"""LintReport / Finding currency: ordering, gating, artifacts, diffing."""
import pytest

from repro.analysis.report import (ERROR, INFO, WARN, Finding, LintReport,
                                   diff, merge, render_diff)


def _f(rule="r1", severity=ERROR, message="m", target="T", **kw):
    return Finding(rule=rule, severity=severity, message=message,
                   target=target, **kw)


def test_finding_normalizes_and_keys():
    a = _f(rows=[3, 1], data={"b": 2, "a": 1})
    assert a.rows == (3, 1)
    assert a.data == (("a", 1), ("b", 2))
    # key excludes the message: rewording a rule must not churn diffs
    b = _f(rows=[3, 1], message="different words")
    assert a.key == b.key
    with pytest.raises(ValueError):
        _f(severity="fatal")


def test_report_gating_and_sorting():
    r = LintReport(target="t")
    r.add(_f(rule="info-rule", severity=INFO))
    assert r.ok() and r.ok(strict=True)
    r.add(_f(rule="warn-rule", severity=WARN))
    assert r.ok() and not r.ok(strict=True)
    r.add(_f(rule="err-rule", severity=ERROR))
    assert not r.ok()
    assert [f.severity for f in r.sorted()] == [ERROR, WARN, INFO]
    assert r.counts() == {ERROR: 1, WARN: 1, INFO: 1}
    assert r.rules_fired() == {"info-rule": 1, "warn-rule": 1,
                               "err-rule": 1}
    assert "err-rule" in r.summary()
    # infos hidden by default, shown on request
    assert "info-rule" not in r.summary()
    assert "info-rule" in r.summary(show_info=True)


def test_json_and_npz_roundtrip(tmp_path):
    r = LintReport(target="DDR4", meta={"channels": 2})
    r.add(_f(rows=(1, 2), data={"x": 1}))
    r.add(_f(rule="r2", severity=WARN, path="a/b.py", line=7))

    loaded = LintReport.from_json(r.to_json())
    assert loaded.target == "DDR4"
    assert {f.key for f in loaded.findings} == {f.key for f in r.findings}

    p = r.save_json(str(tmp_path / "rep.json"))
    assert LintReport.load_json(p).counts() == r.counts()

    p2 = r.save_npz(str(tmp_path / "rep.npz"))
    again = LintReport.load_npz(p2)
    assert again.counts() == r.counts()
    assert again.meta == {"channels": 2}


def test_json_rejects_foreign_format():
    with pytest.raises(ValueError):
        LintReport.from_json('{"format": "something-else", "findings": []}')


def test_diff_and_merge():
    a = LintReport(target="A")
    a.add(_f(rule="both"))
    a.add(_f(rule="only-a"))
    b = LintReport(target="B")
    b.add(_f(rule="both"))
    b.add(_f(rule="only-b"))
    d = diff(a, b)
    assert [f.rule for f in d["added"]] == ["only-b"]
    assert [f.rule for f in d["removed"]] == ["only-a"]
    assert d["common"] == 1
    out = render_diff(a, b)
    assert "+1 -1" in out and "only-b" in out

    m = merge([a, b], target="all")
    assert len(m.findings) == 4 and m.target == "all"
