"""Pallas timing-check kernel vs pure-jnp oracle: shape/dtype sweeps +
semantic equivalence with the engine's earliest_ready."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import DeviceUnderTest, compile_spec
from repro.core import device as D
from repro.kernels import ops, ref
from repro.kernels.timing_check import maxplus_matmul


@pytest.mark.parametrize("Q,K,C", [(8, 16, 8), (32, 30, 10), (1, 1, 1),
                                   (129, 70, 12), (128, 128, 128),
                                   (5, 200, 3)])
def test_maxplus_matches_ref_shapes(Q, K, C):
    rng = np.random.default_rng(Q * 1000 + K * 10 + C)
    T = rng.integers(-(1 << 20), 1 << 20, (Q, K)).astype(np.float32)
    A = rng.integers(0, 500, (K, C)).astype(np.float32)
    A[rng.random((K, C)) < 0.5] = -3e38
    got = maxplus_matmul(jnp.asarray(T), jnp.asarray(A))
    want = ref.maxplus_matmul(jnp.asarray(T), jnp.asarray(A))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_maxplus_dtypes(dtype):
    rng = np.random.default_rng(7)
    T = rng.integers(-1000, 1000, (16, 24)).astype(dtype)
    A = rng.integers(0, 100, (24, 8)).astype(dtype)
    got = maxplus_matmul(jnp.asarray(T), jnp.asarray(A))
    want = ref.maxplus_matmul(jnp.asarray(T, jnp.float32),
                              jnp.asarray(A, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(q=st.integers(1, 40), k=st.integers(1, 40), c=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_maxplus_hypothesis(q, k, c, seed):
    rng = np.random.default_rng(seed)
    T = rng.integers(-(1 << 24), 1 << 24, (q, k)).astype(np.float32)
    A = np.where(rng.random((k, c)) < 0.4,
                 rng.integers(0, 1 << 10, (k, c)).astype(np.float32), -3e38)
    got = np.asarray(maxplus_matmul(jnp.asarray(T), jnp.asarray(A)))
    want = np.asarray(ref.maxplus_matmul(jnp.asarray(T), jnp.asarray(A)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("std,org,tim", [
    ("DDR4", "DDR4_8Gb_x8", "DDR4_2400R"),
    ("LPDDR5", "LPDDR5_8Gb_x16", "LPDDR5_6400"),
    ("HBM3", "HBM3_16Gb", "HBM3_5200"),
])
def test_kernel_readiness_equals_engine_earliest(std, org, tim):
    """The (max,+) path must reproduce the engine's earliest_ready for every
    command after a random replay — the kernel is a drop-in."""
    rng = np.random.default_rng(3)
    dut = DeviceUnderTest(std, org, tim)
    cspec = dut.cspec
    clk = 0
    for _ in range(50):
        sub = {lv: int(rng.integers(int(cspec.level_counts[i + 1])))
               for i, lv in enumerate(cspec.levels[1:])}
        addr = dict(sub, row=int(rng.integers(32)), col=0)
        cmd = dut.probe("RD" if rng.random() < 0.7 else "WR", addr, clk).preq
        if dut.probe(cmd, addr, clk).timing_OK:
            if cmd == "ACT2":
                addr = dict(addr, row=int(dut.act1_row[dut._bank(addr)]))
            dut.issue(cmd, addr, clk=clk)
        clk += int(rng.integers(1, 6))

    dp = D.dyn_params(cspec)
    state = D.init_state(cspec)
    for c, cmd, addr in dut.history:
        sub = jnp.asarray([addr[lv] for lv in cspec.levels[1:]], jnp.int32)
        state = D.issue(cspec, dp, state, jnp.int32(cspec.cmd_id(cmd)), sub,
                        jnp.int32(addr["row"]), jnp.int32(c),
                        jnp.asarray(True))

    keys = ops.build_keys(cspec)
    subs = []
    for _ in range(9):
        subs.append([int(rng.integers(int(cspec.level_counts[i + 1])))
                     for i in range(len(cspec.levels) - 1)])
    subs = jnp.asarray(subs, jnp.int32)
    em = ops.readiness_matrix(cspec, keys, dp.ct_lat, state, subs)
    em_ref = ops.readiness_matrix(cspec, keys, dp.ct_lat, state, subs,
                                  use_pallas=False)
    np.testing.assert_array_equal(np.asarray(em), np.asarray(em_ref))

    for qi in range(subs.shape[0]):
        for ci in range(cspec.n_cmds):
            want = int(D.earliest_ready(cspec, dp, state, jnp.int32(ci),
                                        subs[qi]))
            got = int(em[qi, ci])
            # kernel reports -inf-ish for "no constraint"; engine reports NEG
            if want <= ops.NEG:
                assert got <= ops.NEG
            else:
                assert got == want, (std, qi, cspec.cmd_names[ci], got, want)
