"""Flash-attention Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import gqa_flash_attention


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 0.3,
                       dtype)


@pytest.mark.parametrize("B,H,T,D", [(1, 1, 128, 64), (2, 2, 256, 64),
                                     (1, 4, 100, 32), (1, 1, 300, 128),
                                     (2, 1, 64, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(B, H, T, D, causal):
    q = _rand((B, H, T, D), jnp.float32, 1)
    k = _rand((B, H, T, D), jnp.float32, 2)
    v = _rand((B, H, T, D), jnp.float32, 3)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, tol):
    q = _rand((1, 2, 128, 64), dtype, 4)
    k = _rand((1, 2, 128, 64), dtype, 5)
    v = _rand((1, 2, 128, 64), dtype, 6)
    got = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_gqa_expansion():
    q = _rand((2, 8, 64, 32), jnp.float32, 7)
    k = _rand((2, 2, 64, 32), jnp.float32, 8)
    v = _rand((2, 2, 64, 32), jnp.float32, 9)
    got = gqa_flash_attention(q, k, v, causal=True, use_pallas=True)
    kfull = jnp.repeat(k, 4, axis=1)
    vfull = jnp.repeat(v, 4, axis=1)
    want = ref.flash_attention(q, kfull, vfull, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(t=st.integers(16, 200), d=st.sampled_from([16, 32, 64]),
       causal=st.booleans(), seed=st.integers(0, 1000))
def test_flash_hypothesis(t, d, causal, seed):
    q = _rand((1, 1, t, d), jnp.float32, seed)
    k = _rand((1, 1, t, d), jnp.float32, seed + 1)
    v = _rand((1, 1, t, d), jnp.float32, seed + 2)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


def test_block_size_invariance():
    q = _rand((1, 2, 160, 64), jnp.float32, 11)
    k = _rand((1, 2, 160, 64), jnp.float32, 12)
    v = _rand((1, 2, 160, 64), jnp.float32, 13)
    outs = [flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
            for bq, bk in ((32, 32), (64, 128), (128, 64))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5, rtol=2e-5)
