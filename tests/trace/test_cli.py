"""`python -m repro.trace` CLI: simulate -> artifact -> audit -> HTML, the
--load path, and --fail-on-violations plumbing (the CI smoke contract)."""
import dataclasses

import numpy as np
import pytest

from repro.trace.__main__ import main


def test_cli_end_to_end(tmp_path, capsys):
    out = tmp_path / "trace.npz"
    html = tmp_path / "trace.html"
    jsonl = tmp_path / "trace.jsonl"
    rc = main(["--standard", "DDR4", "--cycles", "4000",
               "--out", str(out), "--html", str(html),
               "--jsonl", str(jsonl), "--fail-on-violations"])
    assert rc == 0
    assert out.exists() and html.exists() and jsonl.exists()
    text = capsys.readouterr().out
    assert "clean" in text
    page = html.read_text()
    assert "bus utilization" in page and "command trace" in page

    # --load path: re-audit + re-render the saved artifact
    html2 = tmp_path / "again.html"
    rc = main(["--load", str(out), "--html", str(html2),
               "--fail-on-violations"])
    assert rc == 0 and html2.exists()
    assert "loaded" in capsys.readouterr().out


def test_cli_fails_on_corrupted_artifact(tmp_path, capsys):
    out = tmp_path / "trace.npz"
    assert main(["--standard", "DDR4", "--cycles", "3000",
                 "--out", str(out)]) == 0
    import repro.trace as T
    tr = T.load(str(out))
    # deterministic corruption: pull the first RD after the first ACT on
    # its bank to one cycle inside the nRCD window
    names = tr.cmd_names
    a = int(np.nonzero(tr.cmd == names.index("ACT"))[0][0])
    r = int(np.nonzero((tr.cmd == names.index("RD"))
                       & (tr.bank == tr.bank[a])
                       & (tr.clk > tr.clk[a]))[0][0])
    clk = tr.clk.copy()
    clk[r] = tr.clk[a] + tr.meta["timings"]["nRCD"] - 1
    order = np.argsort(clk, kind="stable")
    bad = dataclasses.replace(
        tr, clk=clk[order],
        **{f: getattr(tr, f)[order]
           for f in ("cmd", "bank", "row", "bus", "arrive", "hit_ready")})
    T.save(bad, str(out))
    rc = main(["--load", str(out), "--fail-on-violations"])
    text = capsys.readouterr().out
    assert rc == 1 and "ACT->RD" in text


def test_cli_unknown_standard_errors():
    with pytest.raises(SystemExit):
        main(["--standard", "SDRAM66", "--cycles", "100"])
