"""repro.trace capture + format: compaction correctness, batched point
extraction, artifact round-trips, fingerprint safety."""
import numpy as np
import pytest

from repro.core import Simulator
from repro.trace import (CommandTrace, audit, capture, load, read_jsonl,
                         save, spec_fingerprint_hex, write_jsonl)
from repro.trace.capture import FIELDS


@pytest.fixture(scope="module")
def ddr4_run():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    stats, dense = sim.run(2500, interval=2.0, read_ratio=0.7, trace=True)
    return sim, stats, dense


def test_capture_matches_dense_arrays(ddr4_run):
    sim, stats, dense = ddr4_run
    tr = capture(sim.cspec, dense, controller=sim.controller)
    cmds = np.asarray(dense.cmd)
    # every issued dense cell appears exactly once, in issue order
    assert len(tr) == int((cmds >= 0).sum())
    assert len(tr) == int(stats.cmd_counts.sum())
    for i in range(len(tr)):
        t, bus = int(tr.clk[i]), int(tr.bus[i])
        assert cmds[t, bus] == tr.cmd[i]
        assert np.asarray(dense.bank)[t, bus] == tr.bank[i]
        assert np.asarray(dense.row)[t, bus] == tr.row[i]
    # issue order: clk non-decreasing; bus ascending within a cycle
    assert np.all(np.diff(tr.clk) >= 0)
    same = np.diff(tr.clk) == 0
    assert np.all(tr.bus[1:][same] > tr.bus[:-1][same])
    # per-command totals agree with engine Stats
    for c, name in enumerate(tr.cmd_names):
        assert tr.cmd_count(name) == int(stats.cmd_counts[c])


def test_capture_metadata_and_fingerprint(ddr4_run):
    sim, _, dense = ddr4_run
    tr = capture(sim.cspec, dense, controller=sim.controller,
                 frontend=sim.frontend, interval=2.0)
    m = tr.meta
    assert m["standard"] == "DDR4" and m["org_preset"] == "DDR4_8Gb_x8"
    assert m["controller"]["scheduler"] == "FRFCFS"
    assert m["interval"] == 2.0
    assert m["fingerprint"] == spec_fingerprint_hex(sim.cspec)
    # compiled_spec() rebuilds an identical device model
    cs2 = tr.compiled_spec()
    assert spec_fingerprint_hex(cs2) == m["fingerprint"]
    np.testing.assert_array_equal(cs2.ct_lat, sim.cspec.ct_lat)


def test_edited_geometry_trace_reloads_standalone():
    """Benchmarks mutate cspec.rows/columns in place; a trace captured
    from such a spec must still recompile + fingerprint-match from its
    own metadata (compiled_spec replays the geometry edits)."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    sim.cspec.rows = 2
    _, dense = sim.run(600, interval=4.0, trace=True)
    tr = capture(sim.cspec, dense, controller=sim.controller)
    cs2 = tr.compiled_spec()            # must not raise
    assert cs2.rows == 2
    assert audit(None, tr).ok


def test_capture_batched_point_extraction():
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    pts, (stats, dense) = _run_batch_traced(sim, 800, [8.0, 1.0])
    assert np.asarray(dense.cmd).ndim == 3
    with pytest.raises(ValueError):
        capture(sim.cspec, dense)            # batched needs point=
    for j in range(len(pts)):
        tr = capture(sim.cspec, dense, point=j)
        assert len(tr) == int(np.asarray(stats.cmd_counts)[j].sum())


def _run_batch_traced(sim, n_cycles, intervals):
    """Batched trace-emitting run (the executor's capture path)."""
    import jax.numpy as jnp
    from repro.core import device as D
    from repro.core import engine as E
    from repro.core import frontend as F
    pts = [(i, 1.0) for i in intervals]
    fp = F.stack_params(pts, sim.frontend.probe_gap)
    fn = E.RUN_CACHE.get(sim.cspec, sim.controller, sim.frontend, n_cycles,
                         trace=True, batched=True)
    return pts, fn(D.dyn_params(sim.cspec), fp, jnp.uint32(7))


def test_npz_roundtrip(tmp_path, ddr4_run):
    sim, _, dense = ddr4_run
    tr = capture(sim.cspec, dense, controller=sim.controller)
    path = save(tr, str(tmp_path / "t"))      # extension added
    assert path.endswith(".npz")
    back = load(path)
    for f in FIELDS:
        np.testing.assert_array_equal(getattr(back, f), getattr(tr, f))
    assert back.n_cycles == tr.n_cycles
    assert back.cmd_names == tr.cmd_names
    assert back.meta == tr.meta
    # a loaded artifact audits stand-alone (spec recompiled from metadata)
    assert audit(None, back).ok


def test_jsonl_roundtrip(tmp_path, ddr4_run):
    sim, _, dense = ddr4_run
    tr = capture(sim.cspec, dense, controller=sim.controller)
    path = str(tmp_path / "t.jsonl")
    n = write_jsonl(tr, path)
    assert n == len(tr)
    back = read_jsonl(path)
    for f in ("clk", "cmd", "bank", "row", "bus", "arrive"):
        np.testing.assert_array_equal(getattr(back, f), getattr(tr, f))
    assert back.meta == tr.meta


def test_fingerprint_mismatch_rejected(ddr4_run):
    sim, _, dense = ddr4_run
    tr = capture(sim.cspec, dense)
    other = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                      timing_overrides={"nCL": 99}).cspec
    with pytest.raises(ValueError, match="fingerprint"):
        audit(other, tr)
    # explicit override still allowed (and flags plenty of violations)
    rep = audit(other, tr, check_fingerprint=False)
    assert not rep.ok


def test_legacy_three_array_capture(ddr4_run):
    """Bare (cmd, bank, row) tuples still capture (arrive/hit_ready
    default to absent)."""
    sim, _, dense = ddr4_run
    tr = capture(sim.cspec, (dense.cmd, dense.bank, dense.row))
    assert isinstance(tr, CommandTrace)
    assert np.all(tr.arrive == -1)
    # timing audit still runs; scheduler checks skip without request info
    rep = audit(sim.cspec, tr, scheduler="FRFCFS")
    assert rep.ok and "row_hit_first" not in rep.checks
    # without arrive info the visualizer still lanes commands by bank
    # (kind-based refresh fallback), not all onto the refresh lane
    from repro.core.compile import as_system
    from repro.trace.viz import _View
    lanes = _View(as_system(sim.cspec), tr).lanes(tr)
    assert len(np.unique(lanes[lanes < sim.cspec.n_banks])) > 1
