"""Golden-trace equality: the windowed-ring state split must be a pure
layout refactor — the engine's command streams are pinned, column for
column, to sha256 hashes captured from the pre-split dense-ring engine
(``golden_hashes.json``) for every registered standard, plus the
multi-channel path.

These runs are integer state machines end to end (int32 LCG frontend,
int32 timing tables), so the streams are deterministic across platforms
and jax versions; a hash mismatch means the timing semantics changed, not
noise."""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core import ControllerConfig, Simulator
from repro.dse.spec import DEFAULT_SYSTEMS
from repro.trace import capture
from repro.trace.capture import FIELDS

GOLDEN = json.load(open(os.path.join(os.path.dirname(__file__),
                                     "golden_hashes.json")))

pytestmark = pytest.mark.device_timings


def trace_sha256(tr) -> str:
    h = hashlib.sha256()
    for f in FIELDS:
        h.update(np.ascontiguousarray(getattr(tr, f), np.int32).tobytes())
    return h.hexdigest()


@pytest.mark.parametrize("standard", sorted(DEFAULT_SYSTEMS))
def test_command_stream_bit_exact_vs_dense_ring_engine(standard):
    org, tim = DEFAULT_SYSTEMS[standard]
    sim = Simulator(standard, org, tim,
                    controller=ControllerConfig(scheduler="FRFCFS"))
    _, dense = sim.run(3000, interval=2.0, read_ratio=0.7, trace=True)
    tr = capture(sim.cspec, dense)
    want = GOLDEN[standard]
    assert len(tr) == want["n"], (standard, len(tr))
    assert trace_sha256(tr) == want["sha256"], standard


def test_two_channel_stream_bit_exact_vs_dense_ring_engine():
    """The channel-vmapped path through the split state.  The golden hash
    predates per-channel refresh staggering, so the historical in-phase
    behavior is pinned via ``refresh_stagger=False``."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=2,
                    mapper="RoBaRaCoCh",
                    controller=ControllerConfig(refresh_stagger=False))
    _, dense = sim.run(3000, interval=2.0, read_ratio=0.7, trace=True)
    tr = capture(sim.cspec, dense)
    want = GOLDEN["DDR4@2ch"]
    assert len(tr) == want["n"]
    assert trace_sha256(tr) == want["sha256"]


def test_hetero_system_stream_pinned():
    """Golden hash for the heterogeneous path: a 2-group DDR5 +
    CXL-attached DDR4 system (link latency 80) — the group-indexed scan,
    system-level channel digit, and merged-namespace capture are all
    pinned column for column (``group`` column included)."""
    from repro.core import compile_system
    msys = compile_system([
        dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
             timing_preset="DDR5_4800B", channels=2),
        dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
             timing_preset="DDR4_2400R", channels=2, link_latency=80),
    ])
    sim = Simulator(system=msys,
                    controller=ControllerConfig(scheduler="FRFCFS"))
    _, dense = sim.run(3000, interval=2.0, read_ratio=0.7, trace=True)
    tr = capture(msys, dense)
    h = hashlib.sha256()
    for f in FIELDS + ("group",):
        h.update(np.ascontiguousarray(getattr(tr, f), np.int32).tobytes())
    want = GOLDEN["DDR5x2+DDR4x2@80"]
    assert len(tr) == want["n"]
    assert h.hexdigest() == want["sha256"]


@pytest.mark.parametrize("standard", sorted(DEFAULT_SYSTEMS))
def test_command_stream_bit_exact_with_telemetry_enabled(standard):
    """Windowed telemetry must be observationally pure: with
    ``telemetry=W`` the cycle scan is restructured into W-cycle windows
    (plus a ragged tail — 3000 % 256 != 0 here), yet the command stream
    must hash to the SAME golden value as the flat scan, for every
    registered standard."""
    org, tim = DEFAULT_SYSTEMS[standard]
    sim = Simulator(standard, org, tim,
                    controller=ControllerConfig(scheduler="FRFCFS"))
    _, dense, telem = sim.run(3000, interval=2.0, read_ratio=0.7,
                              trace=True, telemetry=256)
    tr = capture(sim.cspec, dense)
    want = GOLDEN[standard]
    assert len(tr) == want["n"], (standard, len(tr))
    assert trace_sha256(tr) == want["sha256"], standard
    assert telem.n_windows == 3000 // 256 + 1
