"""Mutation-sensitivity matrix: every injected single-cycle violation —
one per constraint class (pairwise, window/tFAW, refresh), per standard —
must be flagged by ``trace.audit``.  100% detection is the acceptance
bar; a MISSED cell means the auditor has a blind spot."""
import pytest

from repro.core.controller import ControllerConfig
from repro.core.engine import Simulator
from repro.dse.spec import DEFAULT_SYSTEMS
from repro.trace.audit import audit, constraint_name
from repro.trace.capture import capture
from repro.verify import CLASSES, detected, inject, matrix_table, \
    mutation_matrix

pytestmark = pytest.mark.device_timings


def golden_trace(standard, n_cycles=3000, interval=2.0, read_ratio=0.7):
    # identical knobs to tests/trace/test_audit.py so the process-wide
    # RunCache serves these traces without extra engine compiles
    org, tim = DEFAULT_SYSTEMS[standard]
    sim = Simulator(standard, org, tim, controller=ControllerConfig())
    _, dense = sim.run(n_cycles, interval=interval, read_ratio=read_ratio,
                       trace=True)
    return sim.cspec, capture(sim.cspec, dense, controller=sim.controller,
                              frontend=sim.frontend)


@pytest.fixture(scope="module")
def matrix():
    traces = {std: golden_trace(std) for std in sorted(DEFAULT_SYSTEMS)}
    return mutation_matrix(traces)


def test_matrix_covers_every_standard_and_class(matrix):
    assert {k[0] for k in matrix} == set(DEFAULT_SYSTEMS)
    assert {k[1] for k in matrix} == set(CLASSES)


def test_mutation_matrix_100_percent_detection(matrix):
    missed = {k: v for k, v in matrix.items() if v != "detected"}
    assert not missed, "\n" + matrix_table(matrix)


def test_matrix_table_renders(matrix):
    table = matrix_table(matrix)
    for std in DEFAULT_SYSTEMS:
        assert std in table
    for klass in CLASSES:
        assert klass in table


@pytest.mark.parametrize("klass", CLASSES)
def test_injection_is_minimal_single_cycle(klass):
    """Each injected mutant violates its constraint by exactly one cycle
    (slack -1) — the auditor detects at the tightest possible margin."""
    cspec, tr = golden_trace("DDR4")
    inj = inject(cspec, tr, klass)
    assert inj is not None, f"no injectable {klass} row on DDR4"
    assert inj.lat >= 2
    rep = audit(cspec, inj.trace, check_fingerprint=False)
    assert not rep.ok
    want = constraint_name(cspec, inj.row)
    hits = [v for v in rep.violations
            if v.constraint == want and v.slack == -1]
    assert hits, [str(v) for v in rep.violations[:5]]
    assert detected(cspec, inj)


def test_unmutated_trace_stays_clean():
    """Control: detection is caused by the injection, not by noise."""
    cspec, tr = golden_trace("DDR4")
    rep = audit(cspec, tr)
    assert rep.ok
