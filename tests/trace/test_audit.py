"""Trace auditor: golden traces from the real engine audit clean on every
registered standard; corrupted traces are flagged with the exact violated
constraint; the scalar DUT oracle accepts replayed traces; scheduler
invariants fire on fabricated regressions."""
import dataclasses

import numpy as np
import pytest

from repro.core import ControllerConfig, DeviceUnderTest, Simulator
from repro.dse.spec import DEFAULT_SYSTEMS
from repro.trace import CommandTrace, audit, capture
from repro.trace.audit import constraint_name

pytestmark = pytest.mark.device_timings


def golden_trace(standard, n_cycles=3000, scheduler="FRFCFS",
                 interval=2.0, read_ratio=0.7):
    org, tim = DEFAULT_SYSTEMS[standard]
    sim = Simulator(standard, org, tim,
                    controller=ControllerConfig(scheduler=scheduler))
    _, dense = sim.run(n_cycles, interval=interval, read_ratio=read_ratio,
                       trace=True)
    return sim, capture(sim.cspec, dense, controller=sim.controller,
                        frontend=sim.frontend)


# ---------------------------------------------------------------------------
# Golden traces: the engine's own output must audit clean everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("standard", sorted(DEFAULT_SYSTEMS))
def test_golden_trace_audits_clean(standard):
    sim, tr = golden_trace(standard)
    assert len(tr) > 50, "trace too small to be meaningful"
    rep = audit(sim.cspec, tr)
    assert rep.ok, f"{standard}: " + "; ".join(
        str(v) for v in rep.violations[:5])
    assert rep.n_pairs_checked > 0
    # scheduler checks actually ran for the FR-FCFS golden runs
    assert "row_hit_first" in rep.checks and "age_order" in rep.checks


def test_golden_trace_fcfs_audits_clean():
    sim, tr = golden_trace("DDR4", scheduler="FCFS")
    rep = audit(sim.cspec, tr)
    assert rep.ok
    assert "row_hit_first" not in rep.checks     # FR-FCFS-only invariant
    assert "age_order" in rep.checks


# ---------------------------------------------------------------------------
# Oracle cross-check: the scalar DUT accepts every command of the trace
# ---------------------------------------------------------------------------

def _addr_from_bank(cspec, bank, row):
    counts = cspec.level_counts
    idxs, b = [], int(bank)
    for i in range(len(counts) - 1, 0, -1):
        idxs.append(b % int(counts[i]))
        b //= int(counts[i])
    addr = {lv: v for lv, v in zip(cspec.levels[1:], idxs[::-1])}
    addr["row"] = int(row) if row >= 0 else 0
    addr["col"] = 0
    return addr


@pytest.mark.parametrize("standard", ["DDR4", "LPDDR5", "HBM3"])
def test_dut_accepts_replayed_trace(standard):
    """Independent cross-check: replaying the captured engine trace through
    the scalar DeviceUnderTest with check=True must never raise — both the
    auditor and the oracle agree the engine issued legally."""
    sim, tr = golden_trace(standard, n_cycles=1500)
    org, tim = DEFAULT_SYSTEMS[standard]
    dut = DeviceUnderTest(standard, org, tim)
    for i in range(len(tr)):
        addr = _addr_from_bank(sim.cspec, tr.bank[i], tr.row[i])
        dut.issue(tr.cmd_names[int(tr.cmd[i])], addr, clk=int(tr.clk[i]),
                  check=True)
    assert len(dut.history) == len(tr)


# ---------------------------------------------------------------------------
# Sensitivity: corrupted traces must be flagged with the exact constraint
# ---------------------------------------------------------------------------

def _reorder_by_clk(tr: CommandTrace) -> CommandTrace:
    order = np.argsort(tr.clk, kind="stable")
    cols = {f: getattr(tr, f)[order]
            for f in ("clk", "cmd", "bank", "row", "bus", "arrive",
                      "hit_ready")}
    return dataclasses.replace(tr, **cols)


def test_injected_one_cycle_violation_caught():
    sim, tr = golden_trace("DDR4", n_cycles=4000, read_ratio=1.0)
    names = tr.cmd_names
    i_act, i_rd = names.index("ACT"), names.index("RD")
    nrcd = sim.cspec.timings["nRCD"]
    a = int(np.nonzero(tr.cmd == i_act)[0][0])
    bank = int(tr.bank[a])
    r = int(np.nonzero((tr.cmd == i_rd) & (tr.bank == bank)
                       & (tr.clk > tr.clk[a]))[0][0])
    clk = tr.clk.copy()
    clk[r] = tr.clk[a] + nrcd - 1            # exactly one cycle early
    bad = _reorder_by_clk(dataclasses.replace(tr, clk=clk))
    rep = audit(sim.cspec, bad)
    assert not rep.ok
    hits = [v for v in rep.violations
            if v.prev_cmd == "ACT" and v.cmd == "RD" and v.slack == -1]
    assert hits, [str(v) for v in rep.violations[:5]]
    assert f"lat={nrcd}" in hits[0].constraint
    assert hits[0].bank == bank
    # the exact constraint-table row is identifiable by name
    idx = [i for i in range(len(sim.cspec.ct_prev))
           if sim.cspec.cmd_names[sim.cspec.ct_prev[i]] == "ACT"
           and sim.cspec.cmd_names[sim.cspec.ct_next[i]] == "RD"
           and int(sim.cspec.ct_lat[i]) == nrcd]
    assert any(constraint_name(sim.cspec, i) == hits[0].constraint
               for i in idx)


def test_injected_four_activate_window_violation():
    """Window constraints (tFAW, window=4) are audited through the same
    ring semantics as the engine."""
    sim, tr = golden_trace("DDR4", n_cycles=6000, interval=1.0,
                           read_ratio=1.0)
    names = tr.cmd_names
    i_act = names.index("ACT")
    nfaw = sim.cspec.timings.get("nFAW")
    if nfaw is None:
        pytest.skip("no tFAW on this standard")
    acts = np.nonzero(tr.cmd == i_act)[0]
    # same rank throughout the default single-rank org: squeeze the 5th ACT
    # to 1 cycle before the 1st ACT's window closes
    if len(acts) < 5:
        pytest.skip("not enough ACTs")
    clk = tr.clk.copy()
    target = int(tr.clk[acts[0]]) + nfaw - 1
    if clk[acts[4]] <= target:
        pytest.skip("trace already denser than tFAW")
    clk[acts[4]] = target
    bad = _reorder_by_clk(dataclasses.replace(tr, clk=clk))
    rep = audit(sim.cspec, bad)
    faw = [v for v in rep.violations
           if "window=4" in v.constraint and v.cmd == "ACT"]
    assert faw, [str(v) for v in rep.violations[:8]]


# ---------------------------------------------------------------------------
# Scheduler invariants on fabricated traces
# ---------------------------------------------------------------------------

def _mini_trace(cspec, rows):
    """Build a CommandTrace from (clk, cmd_name, bank, row, arrive,
    hit_ready) tuples."""
    names = list(cspec.cmd_names)
    cols = np.asarray([[c, names.index(n), b, r, a, h]
                       for c, n, b, r, a, h in rows], np.int32).T
    return CommandTrace(
        clk=cols[0], cmd=cols[1], bank=cols[2], row=cols[3],
        bus=np.zeros(len(rows), np.int32), arrive=cols[4],
        hit_ready=cols[5], n_cycles=int(cols[0].max()) + 1,
        cmd_names=names,
        meta={"controller": {"scheduler": "FRFCFS"}})


def test_row_hit_first_violation_flagged():
    cspec = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R").cspec
    # an ACT issued from the queue while a maskable row hit existed
    tr = _mini_trace(cspec, [(10, "ACT", 0, 5, 2, 1)])
    rep = audit(cspec, tr)
    assert rep.checks["row_hit_first"] == 1
    assert rep.violations[0].constraint == "row_hit_first"
    # same event with no hit available is legal
    assert audit(cspec, _mini_trace(cspec, [(10, "ACT", 0, 5, 2, 0)])).ok


def test_age_order_violation_flagged():
    cspec = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R").cspec
    tr = _mini_trace(cspec, [
        (10, "RD", 3, 7, 20, 0),     # younger request served first...
        (40, "RD", 3, 7, 5, 0),      # ...older one after: regression
        (60, "RD", 4, 7, 1, 0),      # different bank: separate group
    ])
    rep = audit(cspec, tr)
    assert rep.checks["age_order"] == 1
    v = [x for x in rep.violations if x.constraint == "age_order"][0]
    assert v.clk == 40 and v.bank == 3
