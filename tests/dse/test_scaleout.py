"""Sweep scale-out: device-sharded batches, donated carries, and the
streamed (bounded in-flight) collection pipeline.

In-process tests cover the single-device invariants; the multi-device
padding/equivalence checks run in a subprocess that forces 4 host
devices before jax initializes."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import ControllerConfig, FrontendConfig
from repro.core import engine as E
from repro.core import frontend as F
from repro.dse import SweepSpec, execute
from repro.dse.executor import _shard_batch

REPO = os.path.join(os.path.dirname(__file__), "..", "..")

SPEC = SweepSpec(systems=("DDR4",), intervals=(8.0, 4.0, 2.0),
                 read_ratios=(1.0, 0.5), n_cycles=400)


def test_shard_batch_empty_devices_raises():
    fp = F.stack_params([(4.0, 1.0), (2.0, 0.5)],
                        FrontendConfig().probe_gap)
    with pytest.raises(ValueError, match="devices"):
        _shard_batch(fp, [])


def test_execute_empty_devices_raises():
    with pytest.raises(ValueError, match="devices"):
        execute(SPEC, devices=[])


def test_run_key_separates_shard_and_donation():
    from repro.core import Simulator
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", channels=4)
    base = E.run_key(sim.cspec, sim.controller, sim.frontend, 300, False,
                     False)
    k_shard = E.run_key(sim.cspec, sim.controller, sim.frontend, 300, False,
                        False, shard=2)
    k_donate = E.run_key(sim.cspec, sim.controller, sim.frontend, 300, False,
                         False, donate=True)
    assert len({base, k_shard, k_donate}) == 3


def test_streamed_collection_depth_invariant():
    """The in-flight bound is a scheduling knob, not a semantic one:
    depth-1 (fully synchronous) and depth-8 pipelines must produce
    identical sweep columns, and the meta must carry the streaming
    accounting."""
    spec = SweepSpec(systems=("DDR4", "DDR5"), intervals=(8.0, 2.0),
                     read_ratios=(1.0,), n_cycles=400)
    r1 = execute(spec, cache=E.RunCache(), max_in_flight=1)
    r8 = execute(spec, cache=E.RunCache(), max_in_flight=8)
    for k in ("throughput_gbps", "latency_ns", "reads_done", "writes_done",
              "cycles"):
        assert np.array_equal(getattr(r1, k), getattr(r8, k)), k
    for res, depth in ((r1, 1), (r8, 8)):
        m = res.meta
        assert m["max_in_flight"] == depth
        assert m["padded_points"] == 0          # single device: no padding
        spans = m["profile"]["spans"]
        assert spans["dispatch"]["calls"] == m["n_groups"]
        assert spans["collect"]["calls"] == m["n_groups"]
        for gm in m["groups"]:
            assert gm["padded"] == 0
            assert gm["wall_s"] >= gm["collect_s"]


def test_executor_reports_profile_spans():
    from repro import telemetry as T
    prof = T.Profiler(E.RUN_CACHE)
    res = execute(SPEC, profiler=prof)
    spans = res.meta["profile"]["spans"]
    assert {"dispatch", "collect"} <= set(spans)
    # the caller's profiler is the one that was fed
    assert prof.report()["spans"]["dispatch"]["calls"] == \
        res.meta["n_groups"]


@pytest.mark.slow
def test_padded_batch_on_four_devices_matches_single_device():
    """3 points on 4 forced host devices: one repeated pad entry is
    simulated and dropped, accounted in the meta, and the unpadded
    columns match a single-device run bit for bit."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core import engine as E
from repro.dse import SweepSpec, execute

assert jax.device_count() == 4
spec = SweepSpec(systems=("DDR4",), intervals=(8.0, 4.0, 2.0),
                 read_ratios=(1.0,), n_cycles=600)
r4 = execute(spec, cache=E.RunCache())                   # all 4 devices
r1 = execute(spec, cache=E.RunCache(), devices=jax.devices()[:1])
assert r4.meta["n_devices"] == 4 and r1.meta["n_devices"] == 1
assert r4.meta["padded_points"] == 1, r4.meta["padded_points"]
assert [g["padded"] for g in r4.meta["groups"]] == [1]
assert r1.meta["padded_points"] == 0
for k in ("throughput_gbps", "latency_ns", "reads_done", "writes_done",
          "probe_cnt", "cycles"):
    assert np.array_equal(getattr(r4, k), getattr(r1, k)), k
print("PADDED-OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    assert "PADDED-OK" in r.stdout
