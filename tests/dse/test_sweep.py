"""DSE subsystem: grid expansion, compile-cache reuse, curve extraction,
artifact persistence."""
import itertools

import numpy as np
import pytest

from repro.core import ControllerConfig, Simulator
from repro.core import engine as E
from repro.dse import (SweepSpec, System, execute, group_points, knee_index,
                       SweepResult)


def test_expand_full_cartesian_grid():
    spec = SweepSpec(
        systems=("DDR4", ("DDR5", "DDR5_16Gb_x8", "DDR5_4800B")),
        controllers=(ControllerConfig(), ControllerConfig(scheduler="FCFS")),
        intervals=(32.0, 4.0, 1.0), read_ratios=(1.0, 0.5),
        n_cycles=1000)
    pts = spec.expand()
    assert spec.grid_shape == (2, 2, 1, 1, 3, 2)
    assert len(pts) == spec.n_points == 24
    combos = {(p.system.standard, p.controller.scheduler, p.interval,
               p.read_ratio) for p in pts}
    want = set(itertools.product(("DDR4", "DDR5"), ("FRFCFS", "FCFS"),
                                 (32.0, 4.0, 1.0), (1.0, 0.5)))
    assert combos == want
    # load points of one (system, controller) pair must be contiguous
    groups = group_points(pts)
    assert len(groups) == 4
    for members in groups.values():
        idx = [i for i, _ in members]
        assert idx == list(range(idx[0], idx[0] + len(idx)))


def test_system_coercion_and_overrides():
    sy = System.make(("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", {"nCL": 20}))
    assert sy.timing_overrides == (("nCL", 20),)
    assert sy.overrides_dict == {"nCL": 20}
    assert System.make("HBM3").org_preset == "HBM3_16Gb"
    with pytest.raises(KeyError):
        System.make("SDRAM66")


def test_system_overrides_order_normalized():
    """Equal overrides in any order/form must compare and hash equal, or
    one physical system would split into two compile groups."""
    a = System.make(("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     (("nCCD_S", 1), ("nBL", 1))))
    b = System.make(("DDR4", "DDR4_8Gb_x8", "DDR4_2400R",
                     {"nBL": 1, "nCCD_S": 1}))
    assert a == b and hash(a) == hash(b)


def test_compile_cache_hit_no_retrace():
    """Identical specs compile exactly once: the second execute() must be
    pure cache hits with zero new jax traces."""
    cache = E.RunCache()
    spec = SweepSpec(systems=("DDR4", "DDR5"), intervals=(16.0, 2.0),
                     read_ratios=(1.0,), n_cycles=400)
    r1 = execute(spec, cache=cache)
    assert r1.meta["n_groups"] == 2
    assert r1.meta["compile_cache_misses"] == 2
    assert r1.meta["traces"] == 2          # one trace per compiled group
    r2 = execute(spec, cache=cache)
    assert r2.meta["compile_cache_misses"] == 0
    assert r2.meta["compile_cache_hits"] == 2
    assert r2.meta["traces"] == 0          # nothing re-traced
    np.testing.assert_array_equal(r1.reads_done, r2.reads_done)


def test_simulator_run_reuses_cache():
    """Two Simulator instances of the same triple share one compiled run."""
    E.RUN_CACHE.clear()
    a = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    b = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    sa = a.run(300, interval=4.0)
    misses = E.RUN_CACHE.misses
    sb = b.run(300, interval=4.0)
    assert E.RUN_CACHE.misses == misses      # second instance: cache hit
    assert E.RUN_CACHE.hits >= 1
    assert int(sa.reads_done) == int(sb.reads_done)


def test_scalar_run_load_sweep_does_not_recompile():
    """interval/read_ratio are traced FrontParams; sweeping them through
    Simulator.run must reuse one compiled program."""
    E.RUN_CACHE.clear()
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    sim.run(300, interval=32.0, read_ratio=1.0)
    assert E.RUN_CACHE.misses == 1
    sim.run(300, interval=2.0, read_ratio=0.5)
    assert E.RUN_CACHE.misses == 1 and E.RUN_CACHE.hits == 1


def test_mutated_cspec_gets_fresh_compile():
    """In-place cspec edits (benchmarks mutate `rows`) must change the
    cache key, and the cached closure must snapshot the spec so later
    retraces can't observe the mutation."""
    E.RUN_CACHE.clear()
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    key_before = E.run_key(sim.cspec, sim.controller, sim.frontend, 300,
                           False, False)
    sim.run(300)
    sim.cspec.rows = 2
    assert E.run_key(sim.cspec, sim.controller, sim.frontend, 300,
                     False, False) != key_before
    sim.run(300)
    assert E.RUN_CACHE.misses == 2      # mutation compiled fresh


def test_executor_matches_simulator_single_runs():
    spec = SweepSpec(systems=("DDR4",), intervals=(8.0, 2.0),
                     read_ratios=(1.0, 0.5), n_cycles=1500)
    res = execute(spec, cache=E.RunCache())
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    for i, pt in enumerate(res.points):
        single = sim.run(1500, interval=pt.interval, read_ratio=pt.read_ratio)
        assert int(res.reads_done[i]) == int(single.reads_done)
        assert int(res.probe_cnt[i]) == int(single.probe_cnt)


def test_latency_monotone_as_interval_shrinks():
    """Latency-throughput extraction on a small DDR4 run: probe latency
    rises monotonically as the streaming interval shrinks (load rises)."""
    spec = SweepSpec(systems=("DDR4",), intervals=(64.0, 8.0, 4.0, 2.0),
                     read_ratios=(1.0,), n_cycles=8000)
    res = execute(spec, cache=E.RunCache())
    (curve,) = res.curves()
    assert list(curve.intervals) == [64.0, 8.0, 4.0, 2.0]
    lat = curve.latency_ns
    assert np.all(np.isfinite(lat))
    assert all(lat[i] < lat[i + 1] for i in range(len(lat) - 1)), lat
    assert 0 < curve.knee < len(lat)
    assert curve.peak_fraction > 0.5


def test_curves_split_distinct_controllers_sharing_scheduler():
    """Two controllers with the same scheduler name are distinct series —
    curves() must not interleave them into one corrupted curve."""
    spec = SweepSpec(systems=("DDR4",),
                     controllers=(ControllerConfig(queue_depth=8),
                                  ControllerConfig(queue_depth=32)),
                     intervals=(16.0, 2.0), read_ratios=(1.0,),
                     n_cycles=400)
    res = execute(spec, cache=E.RunCache())
    cvs = res.curves()
    assert len(cvs) == 2
    for cv in cvs:
        assert list(cv.intervals) == [16.0, 2.0]


def _make_threshold_predicate(threshold):
    """Factory used by the extra-predicate cache-key regression test —
    module-level so two calls yield distinct-but-equal closures."""
    def pred(cspec, ctx):
        return ctx.cand_row < threshold
    return pred


def test_extra_predicate_cache_key_by_value():
    """Regression: `_freeze` used to hash `extra_predicates` callables by
    identity, so two equal configs built from separate factory calls never
    shared a cache entry.  Callables now freeze to qualname + closure
    constants: equal closures -> equal keys, different constants -> new
    key."""
    sim = Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R")
    mk = lambda t: ControllerConfig(
        extra_predicates=(_make_threshold_predicate(t),))
    key = lambda cc: E.run_key(sim.cspec, cc, sim.frontend, 300, False,
                               False)
    assert key(mk(5)) == key(mk(5))          # same constants: shared entry
    assert key(mk(5)) != key(mk(7))          # different closure: distinct
    # end-to-end: the second Simulator with an equal lambda is a cache hit
    E.RUN_CACHE.clear()
    Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", controller=mk(5)).run(200)
    assert E.RUN_CACHE.misses == 1
    Simulator("DDR4", "DDR4_8Gb_x8", "DDR4_2400R", controller=mk(5)).run(200)
    assert E.RUN_CACHE.misses == 1 and E.RUN_CACHE.hits >= 1


def test_knee_index_edges():
    assert knee_index([10.0, 11.0, 25.0, 80.0]) == 2
    assert knee_index([10.0, 11.0, 12.0]) == 2        # never blows up: last
    assert knee_index([float("nan")] * 3) == 2


def test_capture_traces_no_extra_retrace(tmp_path):
    """`capture_traces` swaps each group onto its trace-emitting program —
    it must not *add* traces: TRACE_COUNT advances exactly as in a
    no-capture sweep of the same spec, and per-point artifacts appear."""
    import repro.trace as T
    kw = dict(systems=("DDR4", "HBM3"), intervals=(8.0, 2.0),
              read_ratios=(1.0,), n_cycles=800)
    t0 = E.TRACE_COUNT
    plain = execute(SweepSpec(**kw), cache=E.RunCache())
    d_plain = E.TRACE_COUNT - t0
    assert plain.traces is None

    tdir = str(tmp_path / "traces")
    t0 = E.TRACE_COUNT
    cap = execute(SweepSpec(**kw, capture_traces=tdir), cache=E.RunCache())
    d_cap = E.TRACE_COUNT - t0
    assert d_cap == d_plain                  # no extra re-tracing
    assert cap.meta["n_groups"] == plain.meta["n_groups"]
    # stats identical between the trace and no-trace programs
    np.testing.assert_array_equal(cap.reads_done, plain.reads_done)

    assert len(cap.traces) == len(cap.points)
    for i, pt in enumerate(cap.points):
        tr = cap.traces[i]
        assert len(tr) == int(cap.cmd_counts[i].sum())
        assert tr.meta["interval"] == pt.interval
        assert tr.meta["standard"] == pt.system.standard
        # persisted artifact round-trips and audits clean stand-alone
        back = T.load(cap.meta["trace_artifacts"][i])
        np.testing.assert_array_equal(back.clk, tr.clk)
        assert T.audit(None, back).ok
    # second identical capture sweep is a pure cache hit in a shared cache
    cache = E.RunCache()
    execute(SweepSpec(**kw, capture_traces=True), cache=cache)
    t0 = E.TRACE_COUNT
    r2 = execute(SweepSpec(**kw, capture_traces=True), cache=cache)
    assert E.TRACE_COUNT - t0 == 0
    assert r2.meta["compile_cache_hits"] == 2


def test_save_load_roundtrip(tmp_path):
    from repro.core import FrontendConfig
    spec = SweepSpec(systems=("DDR4",), intervals=(8.0, 1.0),
                     read_ratios=(1.0,), n_cycles=600,
                     controllers=(ControllerConfig(blockhammer_threshold=512),),
                     frontend=FrontendConfig(probe_gap=64))
    res = execute(spec, cache=E.RunCache())
    path = res.save(str(tmp_path / "sweep"))
    assert path.endswith(".npz")
    back = SweepResult.load(path)
    assert len(back) == len(res)
    np.testing.assert_allclose(back.throughput_gbps, res.throughput_gbps)
    np.testing.assert_allclose(back.latency_ns, res.latency_ns)
    for i, pt in enumerate(back.points):
        assert pt.system.standard == res.points[i].system.standard
        assert pt.interval == res.points[i].interval
        assert back.cmd_names[i] == res.cmd_names[i]
        np.testing.assert_array_equal(back.cmd_counts[i], res.cmd_counts[i])
    # cmd_count helper survives the roundtrip
    assert back.cmd_count(0, "RD") == res.cmd_count(0, "RD")
    assert back.cmd_count(0, "NO_SUCH_CMD") == 0
    # non-default controller/frontend configs survive the roundtrip
    assert back.points[0].controller.blockhammer_threshold == 512
    assert back.points[0].frontend.probe_gap == 64


def test_composition_sweep_first_class(tmp_path):
    """Heterogeneous system compositions (DDR5:CXL-DDR4 ratio, link
    latency) sweep as first-class compile-group axes."""
    from repro.dse import Composition
    spec = SweepSpec(
        systems=(Composition((("DDR5", 1), ("DDR4", 1, 40))),
                 Composition((("DDR5", 1), ("DDR4", 1, 160)))),
        intervals=(8.0, 2.0), read_ratios=(1.0,), n_cycles=600)
    pts = spec.expand()
    assert len(pts) == spec.n_points == 4
    assert all(pt.n_channels == 2 for pt in pts)
    res = execute(spec, cache=E.RunCache())
    # one compiled program per composition (link latency splits groups)
    assert res.meta["n_groups"] == 2
    assert res.meta["compile_cache_misses"] == 2
    # link latency is a pure latency knob at moderate load: the longer
    # link must not report lower probe latency
    lat40 = res.latency_ns[[i for i, p in enumerate(res.points)
                            if "40" in p.system.label]]
    lat160 = res.latency_ns[[i for i, p in enumerate(res.points)
                             if "160" in p.system.label]]
    assert np.nanmean(lat160) > np.nanmean(lat40)
    # merged command namespace rides on every point
    assert all("RD" in names for names in res.cmd_names)
    # curves split per composition; peaks are group-correct sums
    cvs = res.curves()
    assert {cv.system for cv in cvs} == {
        "DDR5x1+DDR4x1@40", "DDR5x1+DDR4x1@160"}
    from repro.core import compile_spec, peak_gbps
    want_peak = (peak_gbps(compile_spec("DDR5", "DDR5_16Gb_x8",
                                        "DDR5_4800B"))
                 + peak_gbps(compile_spec("DDR4", "DDR4_8Gb_x8",
                                          "DDR4_2400R")))
    for cv in cvs:
        assert abs(cv.peak_gbps - want_peak) < 1e-9
    # composition points survive the save/load roundtrip
    back = SweepResult.load(res.save(str(tmp_path / "hetero")))
    assert back.points[0].system.label == res.points[0].system.label
    assert back.points[0].n_channels == 2


def test_composition_ignores_channels_axis():
    from repro.dse import Composition
    spec = SweepSpec(
        systems=("DDR4", Composition((("DDR5", 1), ("DDR4", 1)))),
        channels=(1, 2), intervals=(4.0,), read_ratios=(1.0,),
        n_cycles=300)
    pts = spec.expand()
    # plain system: one point per channel count; composition: one point
    plain = [p for p in pts if not isinstance(p.system, Composition)]
    comp = [p for p in pts if isinstance(p.system, Composition)]
    assert {p.n_channels for p in plain} == {1, 2}
    assert len(comp) == 1 and comp[0].n_channels == 2
