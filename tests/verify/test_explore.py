"""Bounded-depth exploration: zero oracle divergences on correct specs
(small configs x standards), and a deliberately miscompiled spec must
yield a minimized, replayable counterexample artifact."""
import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.trace import audit, load
from repro.verify import (explore, load_counterexample, loosen_constraint,
                          tiny_spec)
from repro.verify.explore import SMOKE_CONFIGS, addr_from_bank, bank_sub

pytestmark = pytest.mark.device_timings

#: the acceptance matrix: >= 3 small configs across >= 3 standards
STANDARDS = ("DDR4", "DDR5", "HBM3")


# ---------------------------------------------------------------------------
# Positive path: engine and oracle agree on every reachable command
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("standard", STANDARDS)
@pytest.mark.parametrize("cfg", [c[0] for c in SMOKE_CONFIGS])
def test_exploration_zero_divergences(standard, cfg):
    name, tkw, ckw, ekw = next(c for c in SMOKE_CONFIGS if c[0] == cfg)
    cspec = tiny_spec(standard, **tkw)
    res = explore(cspec, ccfg=ControllerConfig(**ckw), standard=standard,
                  **ekw)
    assert res.ok, "\n".join(str(d) for d in res.divergences[:5])
    # the sweep is non-vacuous: states were expanded, commands issued
    # along some path, and every unique state's full earliest-ready
    # table was compared against the oracle
    assert res.states_explored > 10
    assert res.commands_checked > 0
    assert res.tables_checked > 0


def test_exploration_refresh_pressure():
    """A refresh-focused config: nREFI shrunk so the bounded horizon
    crosses multiple refresh deadlines (REFab/PREab issue legality is
    exercised, not just activates and column commands)."""
    cspec = tiny_spec("DDR4", banks=2, fast=True, nrefi=24)
    res = explore(cspec, depth=30, ccfg=ControllerConfig(queue_depth=2),
                  alphabet=(None, (0, 0, False)), max_frontier=64,
                  standard="DDR4")
    assert res.ok, "\n".join(str(d) for d in res.divergences[:5])
    assert res.commands_checked > 0


def test_truncation_is_reported_not_silent():
    cspec = tiny_spec("DDR4", banks=2)
    res = explore(cspec, depth=6, ccfg=ControllerConfig(queue_depth=2),
                  max_frontier=4)
    assert res.truncated


# ---------------------------------------------------------------------------
# Negative path: a miscompiled spec must produce a minimized
# counterexample that replays outside the harness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def counterexample(tmp_path_factory):
    artifact_dir = str(tmp_path_factory.mktemp("cex"))
    oracle = tiny_spec("DDR4", banks=2, fast=True)
    bad, row = loosen_constraint(oracle, "ACT", "RD", amount=1)
    res = explore(bad, oracle=oracle, depth=12,
                  ccfg=ControllerConfig(queue_depth=2), check_tables=False,
                  artifact_dir=artifact_dir, standard="DDR4",
                  config_doc=dict(standard="DDR4", banks=2, rows=8,
                                  columns=8, fast=True))
    return oracle, bad, row, res


def test_miscompiled_spec_is_caught(counterexample):
    oracle, bad, row, res = counterexample
    assert not res.ok
    assert res.divergences[0].kind == "illegal_issue"
    assert res.counterexample is not None


def test_counterexample_is_minimized(counterexample):
    """The shrunk path keeps exactly the injections needed to reach the
    violation: a single request, then no-ops to the failing cycle."""
    _, _, _, res = counterexample
    cex = res.counterexample
    assert sum(1 for c in cex.path if c != 0) == 1
    assert cex.path[-1] == 0 or len(cex.path) == 1
    assert len(cex.path) == cex.divergence.depth + 1
    # the trace is the minimal command prefix: ends at the violation
    assert int(cex.trace.clk[-1]) == cex.divergence.depth


def test_counterexample_artifact_replays(counterexample):
    """The .npz artifact is self-contained: reload it cold and the
    generic trace auditor flags the exact loosened constraint."""
    oracle, bad, row, res = counterexample
    path = res.counterexample.artifact
    assert path and path.endswith(".npz")

    # plain trace-format load + audit against the pristine spec
    tr = load(path)
    rep = audit(oracle, tr, check_fingerprint=True)   # fingerprint matches
    assert not rep.ok
    lat = int(oracle.ct_lat[row])
    hits = [v for v in rep.violations
            if v.prev_cmd == "ACT" and v.cmd == "RD" and v.slack == -1
            and f"lat={lat}" in v.constraint]
    assert hits, [str(v) for v in rep.violations[:5]]

    # the embedded recipe reconstructs the oracle spec without help
    cspec2, tr2 = load_counterexample(path)
    rep2 = audit(cspec2, tr2)
    assert not rep2.ok
    meta = tr2.meta["counterexample"]
    assert meta["divergence"]["kind"] == "illegal_issue"
    assert meta["path"] == [int(c) for c in res.counterexample.path]


def test_table_divergence_also_caught():
    """check_tables=True catches the miscompilation one layer earlier —
    at the earliest-ready table, before an illegal command ever issues."""
    oracle = tiny_spec("DDR4", banks=2, fast=True)
    bad, _ = loosen_constraint(oracle, "ACT", "RD", amount=1)
    res = explore(bad, oracle=oracle, depth=6,
                  ccfg=ControllerConfig(queue_depth=2), check_tables=True)
    assert not res.ok
    assert res.divergences[0].kind == "earliest_mismatch"
    assert res.counterexample is not None


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def test_bank_sub_roundtrip():
    cspec = tiny_spec("HBM3", banks=4)
    for b in range(int(cspec.n_banks)):
        sub = bank_sub(cspec, b)
        flat = 0
        for i, v in enumerate(sub):
            flat = flat * int(cspec.level_counts[i + 1]) + int(v)
        assert flat == b
        addr = addr_from_bank(cspec, b, 3)
        assert addr["row"] == 3 and addr["col"] == 0


# ---------------------------------------------------------------------------
# Deep tier: wider alphabet, deeper bound, more standards
# ---------------------------------------------------------------------------

@pytest.mark.verify_deep
@pytest.mark.parametrize("standard", ["DDR3", "LPDDR5", "GDDR6", "HBM2",
                                      "GDDR7", "LPDDR6", "HBM4", "DDR5_VRR"])
def test_exploration_deep(standard):
    cspec = tiny_spec(standard, banks=2, fast=True)
    res = explore(cspec, depth=20, ccfg=ControllerConfig(queue_depth=3),
                  max_frontier=256, standard=standard)
    assert res.ok, "\n".join(str(d) for d in res.divergences[:5])
    assert res.commands_checked > 0
