"""Differential comparison against pinned upstream-format command-stream
fixtures: exact reproduction required, and the comparator itself must
report divergences precisely (first index, per-command deltas, length
mismatches)."""
import os

import pytest

from repro.verify import (accuracy_table, compare_streams,
                          diff_against_fixture, dump_cmd_stream, golden_run,
                          parse_cmd_stream)

pytestmark = pytest.mark.device_timings

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
STANDARDS = ("DDR4", "DDR5", "HBM3")


@pytest.mark.parametrize("standard", STANDARDS)
def test_exact_match_against_fixture(standard):
    rep = diff_against_fixture(
        standard, os.path.join(FIXTURES, f"{standard}.cmdstream"))
    assert rep.exact, str(rep)
    assert rep.match_fraction == 1.0
    assert rep.n_golden > 100          # fixtures are non-trivial streams


def test_fixture_metadata_matches_config():
    parsed = parse_cmd_stream(os.path.join(FIXTURES, "DDR4.cmdstream"))
    assert parsed["meta"]["standard"] == "DDR4"
    assert parsed["meta"]["org"] and parsed["meta"]["timing"]
    assert int(parsed["meta"]["n_cycles"]) == 1500


def test_dump_parse_roundtrip():
    cspec, tr = golden_run("DDR4", n_cycles=400)
    text = dump_cmd_stream(cspec, tr)
    parsed = parse_cmd_stream(text)
    assert len(parsed["clk"]) == len(tr.clk)
    assert parsed["clk"] == [int(c) for c in tr.clk]
    assert parsed["cmd"] == [tr.cmd_names[int(c)] for c in tr.cmd]
    # every addr vector spans the full hierarchy + row + col
    width = len(cspec.levels) + 2
    assert all(len(a) == width for a in parsed["addr"])


# ---------------------------------------------------------------------------
# The comparator must *find* divergences, not just bless matches
# ---------------------------------------------------------------------------

def _toy(lines):
    return parse_cmd_stream("\n".join(lines))


def test_comparator_flags_first_divergence():
    g = _toy(["0 ACT 0 0 0 5 0", "4 RD 0 0 0 5 0", "10 PREab 0 0 0 0 0"])
    c = _toy(["0 ACT 0 0 0 5 0", "5 RD 0 0 0 5 0", "10 PREab 0 0 0 0 0"])
    rep = compare_streams("toy", g, c)
    assert not rep.exact
    assert rep.first_divergence == 1
    assert rep.match_fraction == pytest.approx(2 / 3)
    assert "golden=" in rep.divergence_detail


def test_comparator_flags_length_mismatch():
    g = _toy(["0 ACT 0 0 0 5 0", "4 RD 0 0 0 5 0"])
    c = _toy(["0 ACT 0 0 0 5 0"])
    rep = compare_streams("toy", g, c)
    assert not rep.exact
    assert rep.first_divergence == 1
    assert "length mismatch" in rep.divergence_detail


def test_comparator_per_cmd_deltas():
    g = _toy(["0 ACT 0 0 0 5 0", "4 RD 0 0 0 5 0", "8 RD 0 0 0 5 1"])
    c = _toy(["0 ACT 0 0 0 5 0", "4 WR 0 0 0 5 0", "8 RD 0 0 0 5 1"])
    rep = compare_streams("toy", g, c)
    assert rep.per_cmd["RD"] == (2, 1)
    assert rep.per_cmd["WR"] == (0, 1)
    assert rep.per_cmd["ACT"] == (1, 1)


def test_accuracy_table_renders_all_standards():
    reports = [diff_against_fixture(
        s, os.path.join(FIXTURES, f"{s}.cmdstream")) for s in STANDARDS]
    table = accuracy_table(reports)
    for s in STANDARDS:
        assert f"| {s} |" in table
    assert "1.0000" in table


@pytest.mark.verify_deep
@pytest.mark.parametrize("standard", ["DDR3", "LPDDR5", "GDDR6", "HBM2"])
def test_self_consistency_deep(standard):
    """Standards without pinned fixtures: the canonical run must at
    least be reproducible against itself (a fresh second run)."""
    cspec, tr = golden_run(standard)
    golden = parse_cmd_stream(dump_cmd_stream(cspec, tr))
    cspec2, tr2 = golden_run(standard)
    current = parse_cmd_stream(dump_cmd_stream(cspec2, tr2))
    rep = compare_streams(standard, golden, current)
    assert rep.exact, str(rep)
