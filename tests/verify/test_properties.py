"""Property-based scheduler invariants: adversarial replay streams
(bursty, row-conflict-heavy, refresh-starving) must satisfy the audit,
refresh-deadline, window, and starvation bounds — on single-channel,
multi-channel, and heterogeneous systems."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    def settings(**kw):                 # no-op decorator stand-ins so the
        return lambda f: f              # module still collects

    def given(**kw):
        return lambda f: f

    class st:                           # noqa: N801 - mirrors the real name
        @staticmethod
        def integers(*a, **kw):
            return None

        @staticmethod
        def sampled_from(*a, **kw):
            return None

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

from repro.core.controller import ControllerConfig
from repro.verify import STREAMS, verify_properties
from repro.verify.properties import (bursty_stream, refresh_starving_stream,
                                     row_conflict_stream)
from repro.verify.explore import tiny_spec

pytestmark = pytest.mark.device_timings

DDR4 = dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
            timing_preset="DDR4_2400R")
HBM3 = dict(standard="HBM3", org_preset="HBM3_16Gb",
            timing_preset="HBM3_5200")
HETERO = dict(system=[
    dict(standard="DDR5", org_preset="DDR5_16Gb_x8",
         timing_preset="DDR5_4800B", channels=1),
    dict(standard="DDR4", org_preset="DDR4_8Gb_x8",
         timing_preset="DDR4_2400R", channels=1, link_latency=40),
])


# ---------------------------------------------------------------------------
# Smoke tier: one fixed-seed adversarial stream per (system, kind)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(STREAMS))
def test_ddr4_invariants(kind):
    rep = verify_properties(DDR4, kind, n_cycles=4000, seed=7, nrefi=400)
    assert rep.ok, str(rep) + "\n" + "\n".join(rep.details[:8])
    # non-vacuous: requests served, refreshes happened under pressure
    assert rep.info["served"] > 10


def test_hbm3_multichannel_row_conflicts():
    rep = verify_properties(dict(HBM3, channels=2), "row_conflict",
                            n_cycles=4000, seed=3, nrefi=400)
    assert rep.ok, str(rep) + "\n" + "\n".join(rep.details[:8])


def test_hetero_bursty():
    """The PR 5 composition path: per-group audit + per-group refresh
    deadlines under bursty cross-group traffic behind a CXL-style link."""
    rep = verify_properties(HETERO, "bursty", n_cycles=6000, seed=11)
    assert rep.ok, str(rep) + "\n" + "\n".join(rep.details[:8])
    assert rep.info["served"] > 10


def test_refresh_deadline_check_bites():
    """The refresh-deadline property is falsifiable: with refresh
    disabled at the controller, the starving stream must trip it."""
    rep = verify_properties(
        DDR4, "refresh_starving", n_cycles=4000, seed=7, nrefi=400,
        ccfg=ControllerConfig(queue_depth=8, refresh_enabled=False))
    assert rep.checks["refresh_deadline"] > 0
    assert rep.checks["audit_clean"] == 0     # timing stays legal without it


# ---------------------------------------------------------------------------
# Generator well-formedness (cheap, hypothesis-driven)
# ---------------------------------------------------------------------------

def _check_stream(cspec, s):
    assert len(s) > 0
    assert (np.diff(s.arrive) >= 0).all(), "arrivals must be ordered"
    assert (s.chan >= 0).all() and (s.chan < int(cspec.level_counts[0])).all()
    assert s.sub.shape[1] == len(cspec.levels) - 1
    for k in range(s.sub.shape[1]):
        assert (s.sub[:, k] < int(cspec.level_counts[k + 1])).all()
    assert (s.row >= 0).all() and (s.row < int(cspec.rows)).all()


@needs_hypothesis
@settings(max_examples=25)
@given(seed=st.integers(0, 2**31 - 1),
       kind=st.sampled_from(["bursty", "row_conflict", "refresh_starving"]))
def test_adversarial_generators_wellformed(seed, kind):
    cspec = tiny_spec("DDR4", banks=4, rows=16)
    _check_stream(cspec, STREAMS[kind](cspec, seed=seed, n=64))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_adversarial_generators_wellformed_fallback(seed):
    cspec = tiny_spec("DDR4", banks=4, rows=16)
    for kind in STREAMS:
        _check_stream(cspec, STREAMS[kind](cspec, seed=seed, n=64))


def test_generators_are_deterministic():
    cspec = tiny_spec("DDR4", banks=4, rows=16)
    a = bursty_stream(cspec, seed=5)
    b = bursty_stream(cspec, seed=5)
    assert a.fingerprint == b.fingerprint
    c = row_conflict_stream(cspec, seed=5)
    assert a.fingerprint != c.fingerprint


def test_row_conflict_runs_are_bounded():
    """FR-FCFS starvation bounds are conditional on bounded same-row
    pressure; the generator must honor its run-length cap."""
    cspec = tiny_spec("DDR4", banks=2, rows=16)
    s = row_conflict_stream(cspec, seed=9, n=128, run=6)
    run = best = 1
    for k in range(1, len(s)):
        same = (s.chan[k] == s.chan[k - 1]
                and (s.sub[k] == s.sub[k - 1]).all()
                and s.row[k] == s.row[k - 1])
        run = run + 1 if same else 1
        best = max(best, run)
    assert best <= 6


# ---------------------------------------------------------------------------
# Deep tier: hypothesis-driven engine runs + the full standards sweep
# ---------------------------------------------------------------------------

@pytest.mark.verify_deep
@needs_hypothesis
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16),
       kind=st.sampled_from(["bursty", "row_conflict", "refresh_starving"]))
def test_ddr4_invariants_hypothesis(seed, kind):
    rep = verify_properties(DDR4, kind, n_cycles=3000, seed=seed, nrefi=400)
    assert rep.ok, str(rep) + "\n" + "\n".join(rep.details[:8])


@pytest.mark.verify_deep
@pytest.mark.parametrize("standard", ["DDR3", "DDR4", "DDR5", "LPDDR5",
                                      "LPDDR6", "GDDR6", "GDDR7", "HBM2",
                                      "HBM3", "HBM4", "DDR5_VRR"])
def test_all_standards_bursty_deep(standard):
    """All 11 registered standards under adversarial traffic."""
    from repro.dse.spec import DEFAULT_SYSTEMS
    org, tim = DEFAULT_SYSTEMS[standard]
    rep = verify_properties(
        dict(standard=standard, org_preset=org, timing_preset=tim),
        "bursty", n_cycles=4000, seed=13, nrefi=500)
    assert rep.ok, str(rep) + "\n" + "\n".join(rep.details[:8])
