"""Shared test configuration.

Hypothesis profile
------------------
Property-test flakiness had two root causes: per-test ``deadline``
expiries under JIT-compilation jitter, and non-reproducible example
draws in CI.  Both are fixed here at the root instead of per test file:

* the ``repro`` profile (local default) disables deadlines and pins a
  shared ``max_examples`` budget;
* the ``repro-ci`` profile (loaded whenever the ``CI`` environment
  variable is set) additionally sets ``derandomize=True`` so CI draws
  the same examples on every run — a red CI job is always reproducible
  locally by exporting ``CI=1``.

Tests that need randomness outside hypothesis should take the ``rng``
fixture below: a numpy generator seeded from the test's node id, so
every test gets an explicit, stable seed.

Deep-tier gating
----------------
Tests marked ``verify_deep`` (the exhaustive/nightly verification tier,
see ``docs/verification.md``) are skipped unless ``RAMULATOR_VERIFY_DEEP``
is set — the smoke tier stays inside the PR budget.
"""
import os
import zlib

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro", deadline=None, max_examples=25, print_blob=True,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "repro-ci", parent=settings.get_profile("repro"), derandomize=True)
    settings.load_profile("repro-ci" if os.environ.get("CI") else "repro")
except ImportError:                     # pragma: no cover - env dependent
    pass


@pytest.fixture
def rng(request) -> np.random.Generator:
    """Per-test numpy generator with an explicit, stable seed derived
    from the test's node id."""
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


def pytest_collection_modifyitems(config, items):
    if os.environ.get("RAMULATOR_VERIFY_DEEP"):
        return
    skip = pytest.mark.skip(
        reason="deep verification tier — set RAMULATOR_VERIFY_DEEP=1")
    for item in items:
        if "verify_deep" in item.keywords:
            item.add_marker(skip)
